"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernels in
interpret mode on CPU); on a TPU backend the same calls compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bitonic
from .flash_attention import flash_attention as _flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default(interpret: bool | None) -> bool:
    return (not _on_tpu()) if interpret is None else interpret


def _next_pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length()


def _check_sort_keys(x, op: str) -> None:
    """Key-dtype guard for the sorting/merging entry points.

    The bitonic networks compare integer keys and use the dtype max as the
    pad sentinel; float keys (NaN ordering) and non-numeric dtypes have no
    such sentinel.  64-bit keys — the dataplane's packed key+payload-row
    records — are valid but only under an x64 scope: without it jax would
    silently truncate them to 32 bits at the jit boundary, so the guard
    runs *before* dispatch and raises instead.
    """
    dtype = np.dtype(x.dtype)
    if dtype.kind not in "iu":
        raise TypeError(
            f"{op} sorts integer keys only, got dtype {dtype}; the bitonic "
            "network needs an integer pad sentinel"
        )
    if dtype.itemsize == 8 and not jax.config.jax_enable_x64:
        raise TypeError(
            f"{op}: 64-bit keys require an x64 scope "
            "(jax.experimental.enable_x64()); without it the jit boundary "
            "would silently truncate them to 32 bits"
        )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def blockwise_sort(
    x: jax.Array, block: int, interpret: bool | None = None
) -> jax.Array:
    """MergeMarathon segment emission on TPU: sort consecutive ``block``
    chunks of a 1-D stream with the bitonic kernel.

    ``block`` must be a power of two and divide ``x.size`` (the ops-level
    contract; ragged tails are padded by the caller with the dtype max).
    """
    (n,) = x.shape
    if block & (block - 1) or n % block:
        raise ValueError(f"n={n} block={block}: need pow2 block dividing n")
    rows = n // block
    rpt = _row_tile(rows)
    out = bitonic.sort_tiles(
        x.reshape(rows, block),
        rows_per_tile=rpt,
        interpret=_interpret_default(interpret),
    )
    return out.reshape(n)


def _row_tile(rows: int, target: int = 8) -> int:
    """Largest divisor of ``rows`` that is <= target (grid tiling)."""
    for t in range(min(target, rows), 0, -1):
        if rows % t == 0:
            return t
    return 1


def sort_rows_padded(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Row sort for an arbitrary row count: the fused hop engine's one
    device call per switch hop.

    Pads the row dimension up to a multiple of 8 with dtype-max rows so the
    grid always tiles 8 rows per kernel invocation (``sort_rows`` would fall
    to 1-row tiles whenever the row count is prime), sorts, and slices the
    padding back off.  Column count must be a power of two (the bitonic
    contract); ragged *columns* are the caller's padding, done once per hop.
    Keys must be integers narrow enough for the active precision
    (:func:`_check_sort_keys`) — the guard runs pre-dispatch so a 64-bit
    column without an x64 scope raises instead of truncating.
    """
    _check_sort_keys(x, "sort_rows_padded")
    b = x.shape[1]
    if b & (b - 1):
        raise ValueError(f"column count must be a power of two, got {b}")
    return _sort_rows_padded(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _sort_rows_padded(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    rows, b = x.shape
    if rows == 0:
        return x
    pad = (-rows) % 8
    if pad:
        fill = jnp.full((pad, b), jnp.iinfo(x.dtype).max, x.dtype)
        x = jnp.concatenate([x, fill], axis=0)
    out = bitonic.sort_tiles(
        x,
        rows_per_tile=8,
        interpret=_interpret_default(interpret),
    )
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_rows(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Sort each row of (rows, B); B power of two."""
    return bitonic.sort_tiles(
        x,
        rows_per_tile=_row_tile(x.shape[0]),
        interpret=_interpret_default(interpret),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_rows_kv(
    keys: jax.Array, vals: jax.Array, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Row-wise key-value sort (MoE dispatch: key=expert id, val=token idx)."""
    return bitonic.sort_tiles_kv(
        keys,
        vals,
        rows_per_tile=_row_tile(keys.shape[0]),
        interpret=_interpret_default(interpret),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_rows(
    a: jax.Array, b: jax.Array, interpret: bool | None = None
) -> jax.Array:
    """Row-wise merge of two sorted (rows, B) arrays -> (rows, 2B)."""
    return bitonic.merge_tiles(
        a,
        b,
        rows_per_tile=_row_tile(a.shape[0]),
        interpret=_interpret_default(interpret),
    )


def merge_tournament(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Merge ``P`` padded sorted rows (P, B) into one sorted (P*B,) stream —
    the run-arena engine's one device call per segment.

    Rows are runs padded with the dtype max (pads stay at row tails through
    every round and are sliced off by the caller); each round merges adjacent
    row pairs with the log-depth bitonic *merge* network, so the whole
    tournament is ``sum_r log2(2^r B)`` compare-exchange stages instead of a
    fresh log² sort.  P and B must be powers of two — the shape-bucketing
    contract that keeps the jit cache to a handful of compiled shapes.

    On TPU the matrix stays VMEM-resident for all rounds in one Pallas call
    (:func:`repro.kernels.bitonic.tournament_tiles`, up to its VMEM cap);
    elsewhere the *identical* stage schedule lowers through XLA on the host
    backend — Pallas interpret mode would re-trace the network per stage and
    is orders of magnitude slower, which matters because this op backs a
    benchmarked server hot path (unlike the validation-only kernel tests).
    """
    _check_sort_keys(x, "merge_tournament")
    P, B = x.shape
    if P & (P - 1) or B & (B - 1):
        raise ValueError(f"tournament shape must be powers of two, got {x.shape}")
    return _merge_tournament(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _merge_tournament(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    P, B = x.shape
    if _interpret_default(interpret) or P * B > bitonic.TOURNAMENT_MAX_ELEMS:
        return bitonic.tournament_merge_array(x)
    return bitonic.tournament_tiles(x, interpret=False)


def flash_attention(
    q, k, v, *, causal=True, scale=None, block_q=512, block_k=512,
    interpret: bool | None = None,
):
    return _flash_attention(
        q, k, v,
        causal=causal, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=_interpret_default(interpret),
    )


def argsort_padded(
    keys: jax.Array, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """1-D argsort via the kv kernel, padding to the next power of two with
    the dtype max (padding sorts to the tail and is sliced off)."""
    (n,) = keys.shape
    m = _next_pow2(max(n, 2))
    pad = m - n
    kp = jnp.concatenate(
        [keys, jnp.full((pad,), jnp.iinfo(keys.dtype).max, keys.dtype)]
    )
    vp = jnp.arange(m, dtype=jnp.int32)
    ks, vs = sort_rows_kv(kp[None, :], vp[None, :], interpret=interpret)
    return ks[0, :n], vs[0, :n]
