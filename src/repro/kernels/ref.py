"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sort_ref(x: jax.Array) -> jax.Array:
    """Row-wise sort oracle for kernels/bitonic.sort_tiles."""
    return jnp.sort(x, axis=-1)


def sort_kv_ref(keys: jax.Array, vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Key-value sort oracle.  NOTE: the bitonic network is not stable, so we
    compare (key, value-as-tiebreak) ordering only when keys are unique;
    tests with duplicate keys compare keys exactly and values as multisets
    per key group."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(vals, order, axis=-1),
    )


def merge_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row-wise merge oracle: sort the concatenation (inputs are sorted)."""
    return jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Attention oracle: q (B, T, H, d), k/v (B, S, KVH, d), GQA by head
    grouping; fp32 softmax."""
    B, T, H, d = q.shape
    _, S, KVH, _ = k.shape
    group = H // KVH
    scale = scale if scale is not None else 1.0 / (d**0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, T, KVH, group, d)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, kf)
    if causal:
        mask = jnp.tril(jnp.ones((T, S), dtype=bool), k=S - T)
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w, vf)
    return out.reshape(B, T, H, d).astype(q.dtype)
