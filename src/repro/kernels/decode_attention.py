"""Decode attention (one new token vs a long KV cache) as a Pallas kernel.

Decode is memory-bound: each step streams the whole cache through the chip
(roofline table: every decode cell is memory-dominated).  This kernel
splits the cache sequence into VMEM blocks — the paper's range partition
applied to the cache — and merges partial softmax accumulators across
blocks in scratch (LSE merge), exactly the segment/merge structure of
``models.attention.decode_attention`` but at kernel granularity:

    grid = (B * KV, S // block_s)  — sequence blocks sequential
    q tile    (1, G, hd)       one kv-head group's queries
    k/v tiles (1, block_s, hd) cache chunk
    scratch   acc (G, hd) f32, m/l (G, 128) f32

VMEM per step with block_s=1024, hd=128, G<=16: ~1.3 MB.  Lengths mask via
a scalar-prefetch-style (1,)-blocked input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, block_s):
    si = pl.program_id(1)
    ns = pl.num_programs(1)
    G, hd = q_ref.shape[1], q_ref.shape[2]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (G, hd)
    k = k_ref[0].astype(jnp.float32)  # (block_s, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, block_s)
    # visibility: cache positions < length
    cols = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_s), 1
    )
    s = jnp.where(cols < len_ref[0], s, NEG)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == ns - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype
        )


def decode_attention(
    q: jax.Array,
    kcache: jax.Array,
    vcache: jax.Array,
    lengths: jax.Array,
    *,
    block_s: int = 1024,
    scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, KV, hd); lengths: (B,) visible counts.

    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = kcache.shape[1], kcache.shape[2]
    G = H // KV
    bs = min(block_s, S)
    if S % bs:
        raise ValueError(f"S={S} % block_s={bs}")
    scale = scale if scale is not None else hd**-0.5

    qr = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kr = kcache.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vr = vcache.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    lr = jnp.repeat(lengths.astype(jnp.int32), KV)  # (B*KV,)

    grid = (B * KV, S // bs)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk, si: (bk,)),
            pl.BlockSpec((1, G, hd), lambda bk, si: (bk, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda bk, si: (bk, si, 0)),
            pl.BlockSpec((1, bs, hd), lambda bk, si: (bk, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bk, si: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
        interpret=interpret,
    )(lr, qr, kr, vr)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)


def decode_attention_ref(q, kcache, vcache, lengths, scale=None):
    """Pure-jnp oracle."""
    B, H, hd = q.shape
    S, KV = kcache.shape[1], kcache.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd**-0.5
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kcache.astype(jnp.float32))
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vcache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
