"""Bitonic sorting network as a Pallas TPU kernel — the MergeMarathon segment.

The paper's segment is a pipeline of ``y`` match-action stages doing one
compare-swap each, with strictly stage-local memory (RMT).  The TPU-native
equivalent (DESIGN.md §2) is a **bitonic network** over a VMEM-resident tile:
a fixed, data-independent sequence of ``log²(B)`` compare-exchange stages,
each stage a full-width vectorized min/max — i.e. the same hardware idea
(systolic compare-exchange with local memory) at VREG width instead of
packet width.  With tile == segment_length this computes *exactly* the
MergeMarathon emitted stream (see repro.core.marathon).

All compare-exchanges are expressed as reshapes + where/min/max — no gathers
— so the kernel lowers to pure VPU ops.  Tiles are (rows, B) with B a power
of two; the MXU is not involved (sorting is a VPU workload).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stages(n: int):
    """The bitonic network schedule: (k, j) compare-exchange stages."""
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def compare_exchange(x: jax.Array, k: int, j: int) -> jax.Array:
    """One network stage over the last axis (length n, power of two).

    Elements i and i^j are compared; direction ascends iff (i & k) == 0.
    Implemented gather-free: within each 2j-block the first j lanes are the
    ``i`` side and the last j the ``i^j`` side; the direction bit is constant
    per block because 2j divides k.
    """
    *lead, n = x.shape
    nb = n // (2 * j)
    a = x.reshape(*lead, nb, 2, j)
    asc = (jnp.arange(nb) * 2 * j) & k == 0  # (nb,)
    asc = asc[:, None]
    lo, hi = a[..., 0, :], a[..., 1, :]
    mn = jnp.minimum(lo, hi)
    mx = jnp.maximum(lo, hi)
    out = jnp.stack(
        [jnp.where(asc, mn, mx), jnp.where(asc, mx, mn)], axis=-2
    )
    return out.reshape(*lead, n)


def compare_exchange_kv(
    keys: jax.Array, vals: jax.Array, k: int, j: int
) -> tuple[jax.Array, jax.Array]:
    """Key-value variant: values follow their key's swap decision."""
    *lead, n = keys.shape
    nb = n // (2 * j)
    ka = keys.reshape(*lead, nb, 2, j)
    va = vals.reshape(*lead, nb, 2, j)
    asc = ((jnp.arange(nb) * 2 * j) & k == 0)[:, None]
    k0, k1 = ka[..., 0, :], ka[..., 1, :]
    v0, v1 = va[..., 0, :], va[..., 1, :]
    swap = jnp.where(asc, k0 > k1, k0 < k1)
    ko = jnp.stack(
        [jnp.where(swap, k1, k0), jnp.where(swap, k0, k1)], axis=-2
    ).reshape(*lead, n)
    vo = jnp.stack(
        [jnp.where(swap, v1, v0), jnp.where(swap, v0, v1)], axis=-2
    ).reshape(*lead, n)
    return ko, vo


def bitonic_sort_array(x: jax.Array) -> jax.Array:
    """Full network over the last axis (pure jnp; reused inside the kernel)."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic length must be a power of two, got {n}")
    for k, j in _stages(n):
        x = compare_exchange(x, k, j)
    return x


def bitonic_argsort_array(
    keys: jax.Array, vals: jax.Array
) -> tuple[jax.Array, jax.Array]:
    n = keys.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic length must be a power of two, got {n}")
    for k, j in _stages(n):
        keys, vals = compare_exchange_kv(keys, vals, k, j)
    return keys, vals


def bitonic_merge_array(x: jax.Array) -> jax.Array:
    """Merge network only (last k-stage): input rows must be bitonic —
    e.g. ``concat(sorted_a, reversed(sorted_b))``.  log(n) stages instead of
    log²(n): this is the server's two-run merge hot-loop on TPU."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic length must be a power of two, got {n}")
    j = n // 2
    while j >= 1:
        x = compare_exchange(x, n, j)  # k = n -> ascending everywhere
        j //= 2
    return x


def bitonic_merge_rows(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge row-wise sorted ``a`` and ``b`` (..., B) -> (..., 2B).

    ``concat(a, reverse(b))`` is bitonic, so the log(2B)-stage merge network
    sorts it — the batched form of the server's pairwise run merge.  (flip on
    the value, not a Ref: Refs reject negative strides, and lax.rev lowers
    cleanly on TPU.)
    """
    x = jnp.concatenate([a, jnp.flip(b, axis=-1)], axis=-1)
    return bitonic_merge_array(x)


def tournament_merge_array(x: jax.Array) -> jax.Array:
    """Merge all ``P`` sorted rows of ``x`` (P, B) into one sorted (P*B,) row.

    The run-arena merge engine: rows are padded sorted runs (pads = dtype
    max, which every round keeps at the row tail), and each round merges
    adjacent row pairs with the log-depth merge network — rows halve, width
    doubles, log²-free.  ``P`` rounds of work stay device-resident; nothing
    returns to the host until one row remains.  P and B powers of two.
    """
    P, B = x.shape
    if P & (P - 1) or B & (B - 1):
        raise ValueError(f"tournament shape must be powers of two, got {x.shape}")
    if jnp.dtype(x.dtype).kind not in "iu":
        # The row pads are the dtype max; only integer keys have a total
        # order in which that sentinel is guaranteed maximal (float NaNs
        # break the compare-exchange invariant silently).
        raise TypeError(
            f"tournament merges integer keys only, got dtype {x.dtype}"
        )
    while x.shape[0] > 1:
        x = bitonic_merge_rows(x[0::2], x[1::2])
    return x[0]


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _sort_kernel(x_ref, o_ref):
    o_ref[...] = bitonic_sort_array(x_ref[...])


def _sort_kv_kernel(k_ref, v_ref, ko_ref, vo_ref):
    ko, vo = bitonic_argsort_array(k_ref[...], v_ref[...])
    ko_ref[...] = ko
    vo_ref[...] = vo


def _merge_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = bitonic_merge_rows(a_ref[...], b_ref[...])


def _tournament_kernel(x_ref, o_ref):
    o_ref[...] = tournament_merge_array(x_ref[...])[None, :]


def sort_tiles(
    x: jax.Array,
    *,
    rows_per_tile: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Sort each row of ``x`` (rows, B) with the bitonic kernel.

    BlockSpec tiles (rows_per_tile, B) into VMEM; B power of two.  VMEM
    working set = rows_per_tile * B * itemsize (plus the network's
    temporaries) — callers pick rows_per_tile so this stays ≪ 16 MB.
    """
    rows, n = x.shape
    if rows % rows_per_tile:
        raise ValueError(f"rows {rows} % rows_per_tile {rows_per_tile} != 0")
    grid = (rows // rows_per_tile,)
    spec = pl.BlockSpec((rows_per_tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        _sort_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        interpret=interpret,
    )(x)


def sort_tiles_kv(
    keys: jax.Array,
    vals: jax.Array,
    *,
    rows_per_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Key-value tile sort (the MoE dispatch primitive: keys=expert ids,
    vals=token indices)."""
    rows, n = keys.shape
    if keys.shape != vals.shape:
        raise ValueError("keys/vals shape mismatch")
    if rows % rows_per_tile:
        raise ValueError(f"rows {rows} % rows_per_tile {rows_per_tile} != 0")
    grid = (rows // rows_per_tile,)
    spec = pl.BlockSpec((rows_per_tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        _sort_kv_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(keys.shape, keys.dtype),
            jax.ShapeDtypeStruct(vals.shape, vals.dtype),
        ),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        interpret=interpret,
    )(keys, vals)


def merge_tiles(
    a: jax.Array,
    b: jax.Array,
    *,
    rows_per_tile: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Merge row-wise sorted ``a`` and ``b`` (rows, B) -> (rows, 2B)."""
    rows, n = a.shape
    if a.shape != b.shape:
        raise ValueError("a/b shape mismatch")
    if rows % rows_per_tile:
        raise ValueError(f"rows {rows} % rows_per_tile {rows_per_tile} != 0")
    grid = (rows // rows_per_tile,)
    in_spec = pl.BlockSpec((rows_per_tile, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((rows_per_tile, 2 * n), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, 2 * n), a.dtype),
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        interpret=interpret,
    )(a, b)


#: VMEM budget for the whole-tournament kernel: the full (P, B) run matrix
#: plus one round of temporaries must stay on-chip (~16 MB/core).
TOURNAMENT_MAX_ELEMS = 1 << 22


def tournament_tiles(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Run-arena tournament as one Pallas call: the entire padded run matrix
    lives in VMEM and every merge round happens without touching HBM.

    No grid — rounds couple all rows, so the matrix is a single block.
    ``P * B`` is capped at :data:`TOURNAMENT_MAX_ELEMS` (the VMEM budget);
    larger arenas are the caller's responsibility to split (``ops.
    merge_tournament`` lowers the identical network through plain XLA
    off-TPU, where no such cap applies).
    """
    P, B = x.shape
    if P & (P - 1) or B & (B - 1):
        raise ValueError(f"tournament shape must be powers of two, got {x.shape}")
    if P * B > TOURNAMENT_MAX_ELEMS:
        raise ValueError(
            f"tournament matrix {P}x{B} exceeds the VMEM budget "
            f"({TOURNAMENT_MAX_ELEMS} elements)"
        )
    out = pl.pallas_call(
        _tournament_kernel,
        out_shape=jax.ShapeDtypeStruct((1, P * B), x.dtype),
        interpret=interpret,
    )(x)
    return out[0]
